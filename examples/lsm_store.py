"""LSM KV store with per-run bloomRF filter blocks — the paper's RocksDB
integration (§9), via the real subsystem (`repro.store`, DESIGN.md §10).

Writes buffer in a memtable and flush to immutable sorted runs, each with
a bloomRF filter block and min/max fences; leveled compaction merges runs
(same-class filter state ORs, class-graduating merges rebuild through the
insert path).  GET and SCAN probe *all* live runs' filters in ONE fused
gather over the stacked state before touching any run — we count the run
reads the filters avoided, exactly the point-range unification the paper
contributes.

    PYTHONPATH=src python examples/lsm_store.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro import FilterSpec, open_filter

if __name__ == "__main__":
    rng = np.random.default_rng(7)
    handle = open_filter(FilterSpec(dtype="u32", placement="store",
                                    memtable_limit=4_000, level0_runs=6,
                                    fanout=4, bits_per_key=16.0))
    db = handle.store
    keys = rng.integers(0, 1 << 31, 60_000, dtype=np.uint64)
    for i, k in enumerate(keys):
        db.put(int(k), f"v{i}")
    for k in keys[:2_000]:                       # churn: deletes flush as
        db.delete(int(k))                        # tombstone entries
    db.flush()
    print(f"{db.n_runs} runs over {len(db.levels)} levels "
          f"({db.stats.flushes} flushes, {db.stats.compactions} compactions: "
          f"{db.stats.or_merges} OR-merges, "
          f"{db.stats.rebuild_merges} rebuilds)")

    hits = sum(v is not None for v in db.get_many(keys[2_000:2_400]))
    gone = sum(v is not None for v in db.get_many(keys[:400]))
    phantom = sum(v is not None for v in db.get_many(
        rng.integers(1 << 31, 1 << 32, 400, dtype=np.uint64)))
    print(f"GET: {hits}/400 live found, {gone} deleted resurrected, "
          f"{phantom} phantom hits")

    lo = rng.integers(0, 1 << 31, 200, dtype=np.uint64)
    n_results = sum(len(r) for r in db.scan_many(lo, lo + (1 << 16)))
    s = db.stats
    print(f"SCAN x200 (|R|=2^16): {n_results} results, "
          f"{s.runs_probed_per_scan:.2f} runs touched/scan "
          f"of {db.n_runs} live")
    skipped = s.scan_fence_skips + s.scan_filter_skips
    print(f"filters+fences pruned {skipped}/{s.scan_runs_considered} "
          f"run reads ({skipped / max(s.scan_runs_considered, 1):.1%}), "
          f"{s.bytes_not_read >> 20} MiB not read")
