"""Mini LSM KV store with per-SST bloomRF filters — the paper's RocksDB
integration (§9), reproduced standalone.

Writes go to a memtable; on flush, an immutable SST (sorted run) is created
with its own bloomRF over the keys.  GET consults each SST's filter before
"reading" it; SCAN(lo, hi) consults each SST's *range* filter — exactly the
point-range unification the paper contributes.  We count avoided SST reads.

    PYTHONPATH=src python examples/lsm_store.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import jax.numpy as jnp

from repro.core import BloomRF, basic_layout


import jax


class SST:
    def __init__(self, kv: dict, bits_per_key=16.0):
        self.keys = np.asarray(sorted(kv), np.uint64)
        self.vals = [kv[k] for k in sorted(kv)]
        self.layout = basic_layout(64, len(kv), bits_per_key, delta=7)
        self.filter = BloomRF(self.layout)
        self.state = self.filter.build(jnp.asarray(self.keys))
        self.point = jax.jit(self.filter.point)   # compile probes once
        self.rquery = jax.jit(self.filter.range)
        self.reads = 0

    def get(self, k):
        self.reads += 1
        i = np.searchsorted(self.keys, k)
        if i < len(self.keys) and self.keys[i] == k:
            return self.vals[i]
        return None

    def scan(self, lo, hi):
        self.reads += 1
        a, b = np.searchsorted(self.keys, [lo, hi + 1])
        return list(zip(self.keys[a:b], self.vals[a:b]))


class MiniLSM:
    def __init__(self, memtable_size=10_000):
        self.mem: dict = {}
        self.ssts: list = []
        self.memtable_size = memtable_size
        self.stats = {"filter_negatives": 0, "sst_reads": 0}

    def put(self, k, v):
        self.mem[np.uint64(k)] = v
        if len(self.mem) >= self.memtable_size:
            self.ssts.append(SST(self.mem))
            self.mem = {}

    def get(self, k):
        k = np.uint64(k)
        if k in self.mem:
            return self.mem[k]
        for sst in reversed(self.ssts):
            if not bool(sst.point(sst.state, jnp.uint64(k))):
                self.stats["filter_negatives"] += 1
                continue
            self.stats["sst_reads"] += 1
            v = sst.get(k)
            if v is not None:
                return v
        return None

    def scan(self, lo, hi):
        out = [(k, v) for k, v in self.mem.items() if lo <= k <= hi]
        for sst in self.ssts:
            if not bool(sst.rquery(sst.state, jnp.uint64(lo),
                                   jnp.uint64(hi))):
                self.stats["filter_negatives"] += 1
                continue
            self.stats["sst_reads"] += 1
            out.extend(sst.scan(lo, hi))
        return sorted(out)


if __name__ == "__main__":
    rng = np.random.default_rng(7)
    db = MiniLSM()
    keys = rng.integers(0, 1 << 40, 60_000, dtype=np.uint64)
    for i, k in enumerate(keys):
        db.put(k, f"v{i}")
    print(f"{len(db.ssts)} SSTs + {len(db.mem)} memtable entries")

    hits = sum(db.get(k) is not None for k in keys[:400])
    miss = sum(db.get(k) is not None
               for k in rng.integers(0, 1 << 40, 400, dtype=np.uint64))
    print(f"GET: {hits}/400 present found, {miss} phantom hits")

    n_results = 0
    for _ in range(100):
        lo = rng.integers(0, 1 << 40)
        n_results += len(db.scan(lo, lo + 2 ** 16))
    print(f"SCAN x100 (|R|=2^16): {n_results} results")
    total = db.stats["filter_negatives"] + db.stats["sst_reads"]
    print(f"filter pruned {db.stats['filter_negatives']}/{total} SST reads "
          f"({db.stats['filter_negatives']/max(total,1):.1%})")
