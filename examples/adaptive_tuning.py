"""Self-designing filters: static advisor vs the workload-adaptive tuner
(`repro.tune`, DESIGN.md §16).

Two identical LSM stores see the same skewed workload — zipf-clustered
keys and short scans with correlated near misses.  The static store
keeps its capacity-ladder layouts; the adaptive one samples the live
scan bounds, re-solves the layout over equal-budget candidates, and
lands the winning geometry at class-graduating compactions (where a
rebuild is already being paid for).  Same keys, same bits per key,
fewer false positives.

    PYTHONPATH=src python examples/adaptive_tuning.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro import FilterSpec, open_filter


def empty_range_fpr(handle, data, rng, n=3_000, width=256):
    """Observed FPR: fraction of ground-truth-empty scans the filters pass."""
    lo = data[rng.integers(0, len(data), n)] + rng.integers(
        width, 32 * width, n, dtype=np.uint64)          # near misses
    hi = np.minimum(lo + np.uint64(width - 1), np.uint64((1 << 32) - 1))
    srt = np.sort(data)
    i = np.searchsorted(srt, lo)
    empty = ~((i < len(srt)) & (srt[np.minimum(i, len(srt) - 1)] <= hi))
    fence, filt = handle.store.probe_runs(lo[empty], hi[empty])
    return float((fence & filt).any(axis=1).mean())


if __name__ == "__main__":
    rng = np.random.default_rng(11)
    z = rng.random(30_000) ** 4                          # heavy skew
    data = np.minimum((z * (1 << 31)).astype(np.uint64)
                      + rng.integers(0, 1 << 22, 30_000, dtype=np.uint64),
                      np.uint64((1 << 32) - 1))
    starts = data[rng.integers(0, len(data), 768)] + np.uint64(1)
    for tuning in ("auto", "adaptive"):
        h = open_filter(FilterSpec(dtype="u32", placement="store",
                                   memtable_limit=1_000, level0_runs=3,
                                   tuning=tuning))
        for i, k in enumerate(data[:15_000]):            # load half
            h.put(int(k), i)
        h.flush()
        for s in range(0, 768, 64):                      # the observed scans
            h.scan_many(starts[s:s + 64], starts[s:s + 64] + np.uint64(255))
        for i, k in enumerate(data[15_000:]):            # compactions fire
            h.put(int(k), 15_000 + i)
        h.flush()
        rep = h.retune_report()
        fpr = empty_range_fpr(h, data, np.random.default_rng(99))
        print(f"{tuning:>8}: observed FPR {fpr:.4f} at "
              f"{h.size_bits() / len(np.unique(data)):.1f} bits/key, "
              f"retunes={rep['retunes']}")
        for ev in rep.get("events", []):
            print(f"          class {ev['class_deltas']} -> "
                  f"{ev['tuned_deltas']} (predicted win {ev['win']:.0%})")
